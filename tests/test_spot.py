"""SpotBook / SpotCloud property + differential suite (PR 10).

The spot baseline's market core (``SpotBook``, sim/cloud.py) is a pure
state machine, so the paper-semantics contract is pinned directly:

* preemption fires iff the spot price exceeds the launch bid, and only
  after a full reclamation-notice window;
* bills never exceed the launch-bid rate (winners pay
  ``min(spot, bid)``);
* notices are rescindable — a price dip back under the bid cancels;
* leaves are conserved across preempt/regrant: every leaf is free or
  owned by exactly one tenant, and grants only consume free leaves;
* unfilled requests expire at the end of each clearing (one-shot).

A hand-rolled oracle re-implements the clearing rule independently and
is differential-tested against ``SpotBook`` on randomized op sequences.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAS_HYPOTHESIS = False

    def given(**kw):                     # run each property once on a
        def deco(fn):                    # seeded op stream when
            def run():                   # hypothesis is unavailable
                fn(ops=_seeded_ops(random.Random(7)))
            return run
        return deco

    def settings(**kw):
        return lambda fn: fn

from repro.sim.cloud import SpotBook

FLOOR = 2.0
NOTICE = 120.0
EPS = 1e-9


def _seeded_ops(rng, n=200):
    return [(rng.choice(["request", "release", "clear"]),
             rng.randrange(4), rng.uniform(0.5, 10.0), rng.randrange(8))
            for _ in range(n)]


if HAS_HYPOTHESIS:
    op_strategy = st.lists(
        st.tuples(
            st.sampled_from(["request", "release", "clear"]),
            st.integers(0, 3),                # tenant id
            st.floats(0.5, 10.0),             # bid
            st.integers(0, 7),                # leaf selector
        ), min_size=1, max_size=80)
else:
    op_strategy = None


def drive(book, ops):
    """Apply an op sequence; yield (now, grants, preempts, snapshot)
    after every clear.  Time advances one 60 s tick per clear."""
    now = 0.0
    for op, tid, bid, leafsel in ops:
        if op == "request":
            book.request(f"t{tid}", bid)
        elif op == "release":
            held = book.held(f"t{tid}")
            if held:
                book.release(held[leafsel % len(held)])
        else:
            pre_owner = dict(book.owner)
            pre_bid = dict(book.launch_bid)
            pre_notice = dict(book.notice)
            grants, preempts = book.clear(now)
            yield now, grants, preempts, pre_owner, pre_bid, pre_notice
            now += 60.0


def make_book():
    return SpotBook(range(6), FLOOR, NOTICE)


@settings(max_examples=60, deadline=None)
@given(ops=op_strategy)
def test_preemption_iff_spot_exceeds_bid(ops):
    book = make_book()
    for now, grants, preempts, pre_owner, pre_bid, pre_notice \
            in drive(book, ops):
        # fired preemptions: bid was under spot AND the notice window
        # had fully elapsed
        for tenant, leaf in preempts:
            assert pre_bid[leaf] < book.spot - EPS
            assert pre_notice[leaf] <= now
            assert now - pre_notice[leaf] >= -EPS
        # survivors: at or above spot, or still inside their window
        for leaf, owner in book.owner.items():
            if owner is None:
                continue
            if book.launch_bid[leaf] < book.spot - EPS:
                assert book.notice[leaf] > now
            else:
                assert leaf not in book.notice   # rescinded / never cut


@settings(max_examples=60, deadline=None)
@given(ops=op_strategy)
def test_bills_never_exceed_bid_rate(ops):
    book = make_book()
    for _now, _g, _p, *_ in drive(book, ops):
        for leaf, owner in book.owner.items():
            if owner is None:
                continue
            rate = book.bill_rate(leaf)
            assert rate <= book.launch_bid[leaf] + EPS
            assert rate <= book.spot + EPS
        assert book.spot >= FLOOR - EPS


@settings(max_examples=60, deadline=None)
@given(ops=op_strategy)
def test_notice_window_semantics(ops):
    """A notice never fires early, and rescinds when the price recedes
    below the launch bid."""
    book = make_book()
    for now, _g, preempts, _po, pre_bid, pre_notice in drive(book, ops):
        for _tenant, leaf in preempts:
            # the deadline had passed, and the full window elapsed
            # since issue (issue time = deadline - NOTICE)
            assert pre_notice[leaf] <= now
            assert now - (pre_notice[leaf] - NOTICE) >= NOTICE - EPS
        for leaf, owner in book.owner.items():
            if owner is not None \
                    and book.launch_bid[leaf] >= book.spot - EPS:
                assert leaf not in book.notice


@settings(max_examples=60, deadline=None)
@given(ops=op_strategy)
def test_leaf_conservation(ops):
    book = make_book()
    leaves = set(book.leaves)
    for now, grants, preempts, pre_owner, *_ in drive(book, ops):
        assert set(book.owner) == leaves            # no leaf appears/dies
        # grants only consumed leaves free after this clear's preempts
        preempted = {leaf for _t, leaf in preempts}
        for tenant, leaf, _bid in grants:
            assert pre_owner[leaf] is None or leaf in preempted
            assert book.owner[leaf] == tenant
        # requests are one-shot: nothing survives the clear
        assert book.requests == []
        # held + free partitions the capacity
        held = sum(1 for o in book.owner.values() if o is not None)
        free = sum(1 for o in book.owner.values() if o is None)
        assert held + free == len(leaves)


@settings(max_examples=60, deadline=None)
@given(ops=op_strategy)
def test_spot_is_marginal_demand_clearing_price(ops):
    """spot == floor when standing demand fits capacity, else the
    highest rejected standing bid."""
    book = make_book()
    for op, tid, bid, leafsel in ops:
        if op == "request":
            book.request(f"t{tid}", bid)
        elif op == "release":
            held = book.held(f"t{tid}")
            if held:
                book.release(held[leafsel % len(held)])
        else:
            standing = sorted(
                [book.launch_bid[l] for l, o in book.owner.items()
                 if o is not None]
                + [r.bid for r in book.requests], reverse=True)
            C = len(book.leaves)
            want = max(FLOOR, standing[C]) if len(standing) > C \
                else FLOOR
            book.clear(0.0)
            assert book.spot == pytest.approx(want)


# ---------------------------------------------------------------------------
# Differential: hand-rolled oracle vs SpotBook on the same op stream.
# ---------------------------------------------------------------------------
class SpotOracle:
    """Independent re-implementation of the spot semantics with plain
    dict/list scans (no shared code with SpotBook)."""

    def __init__(self, n_leaves, floor, notice_s):
        self.n = n_leaves
        self.floor = floor
        self.notice_s = notice_s
        self.own = {}                  # leaf -> (tenant, bid)
        self.pending = []              # (seq, tenant, bid)
        self.cut = {}                  # leaf -> deadline
        self.price = floor
        self.seq = 0

    def request(self, tenant, bid):
        self.pending.append((self.seq, tenant, bid))
        self.seq += 1

    def release(self, leaf):
        self.own.pop(leaf, None)
        self.cut.pop(leaf, None)

    def clear(self, now):
        allbids = sorted([b for _t, b in self.own.values()]
                         + [b for _s, _t, b in self.pending],
                         reverse=True)
        self.price = self.floor
        if len(allbids) > self.n:
            self.price = max(self.floor, allbids[self.n])
        for leaf in list(self.own):
            if self.own[leaf][1] < self.price - 1e-9:
                self.cut.setdefault(leaf, now + self.notice_s)
            else:
                self.cut.pop(leaf, None)
        preempts = []
        for leaf in sorted(self.cut):
            if self.cut[leaf] <= now:
                preempts.append((self.own[leaf][0], leaf))
                del self.own[leaf]
                del self.cut[leaf]
        free = sorted(set(range(self.n)) - set(self.own))
        grants = []
        for s, t, b in sorted(self.pending, key=lambda x: (-x[2], x[0])):
            if not free or b < self.price - 1e-9 \
                    or b < self.floor - 1e-9:
                continue
            leaf = free.pop(0)
            self.own[leaf] = (t, b)
            grants.append((t, leaf, b))
        self.pending = []
        return grants, preempts


@settings(max_examples=80, deadline=None)
@given(ops=op_strategy)
def test_differential_vs_oracle(ops):
    book = make_book()
    oracle = SpotOracle(6, FLOOR, NOTICE)
    now = 0.0
    for op, tid, bid, leafsel in ops:
        if op == "request":
            book.request(f"t{tid}", bid)
            oracle.request(f"t{tid}", bid)
        elif op == "release":
            held = book.held(f"t{tid}")
            if held:
                leaf = held[leafsel % len(held)]
                book.release(leaf)
                oracle.release(leaf)
        else:
            g1, p1 = book.clear(now)
            g2, p2 = oracle.clear(now)
            assert g1 == g2
            assert sorted(p1) == sorted(p2)
            assert book.spot == pytest.approx(oracle.price)
            assert {l: o for l, o in book.owner.items()
                    if o is not None} \
                == {l: t for l, (t, _b) in oracle.own.items()}
            now += 60.0


def test_spotcloud_toy_run_billing_and_conservation():
    """SpotCloud end-to-end on a toy scenario: leaves conserved, every
    tenant's cumulative bill bounded by its max launch bid x wall
    hours x capacity."""
    from repro.sim.simulator import ScenarioConfig, run_once
    cfg = ScenarioConfig(regime="slight", seed=3, duration_s=1800.0,
                         tick_s=60.0)
    r = run_once("spot", cfg)
    assert r.stats["grants"] > 0
    assert all(c >= 0.0 for c in r.cost.values())
    assert all(0.0 <= p <= 1.0 + 1e-6 for p in r.perf.values())
