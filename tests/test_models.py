"""Per-arch smoke tests (reduced configs, real values on CPU) + decode/
prefill consistency + loss sanity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, applicable_shapes, SHAPES
from repro.models import layers as L
from repro.models import model as M
from repro.optim import AdamWConfig, make_train_state, adamw_update

KEY = jax.random.key(0)


def tiny_batch(cfg, B=2, S=32):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = jnp.full(
            (B, cfg.num_prefix_tokens, cfg.d_model), 0.01, jnp.float32)
    if cfg.frontend == "audio_stub":
        batch["encoder_embeds"] = jnp.full((B, 16, cfg.d_model), 0.01,
                                           jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(name):
    """One forward + one AdamW train step on a reduced config of the same
    family: output shapes correct, no NaNs."""
    cfg = ARCHS[name].reduced()
    params = M.init_params(cfg, KEY)
    batch = tiny_batch(cfg)
    logits, _ = M.forward(params, cfg, batch)
    S_total = batch["tokens"].shape[1] + (
        cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (2, S_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch, L.moe_dense))(params)
    assert np.isfinite(float(loss))
    state = make_train_state(params, AdamWConfig())
    state, gnorm = adamw_update(state, grads, AdamWConfig())
    assert np.isfinite(float(gnorm))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_prefill_decode(name):
    cfg = ARCHS[name].reduced()
    params = M.init_params(cfg, KEY)
    B, S = 2, 16
    batch = tiny_batch(cfg, B, S)
    logits, cache = M.prefill(params, cfg, batch, max_len=S + 8)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = M.decode_step(params, cfg, cache, tok,
                                    jnp.array(S, jnp.int32))
    assert logits2.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def test_decode_matches_forward_logits():
    """Teacher-forced decode reproduces full-forward logits (causal LMs):
    prefill tokens[:, :t] then decode tokens[t] => logits == forward."""
    cfg = get_config("qwen3-0.6b").reduced(num_layers=3)
    params = M.init_params(cfg, KEY)
    B, S = 1, 12
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full_logits, _ = M.forward(params, cfg, {"tokens": tokens})
    t = S - 1
    _, cache = M.prefill(params, cfg, {"tokens": tokens[:, :t]},
                         max_len=S + 2)
    dec_logits, _ = M.decode_step(params, cfg, cache, tokens[:, t:t + 1],
                                  jnp.array(t, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=2e-3, atol=2e-3)


def test_ssm_decode_matches_forward():
    cfg = get_config("mamba2-780m").reduced(num_layers=2)
    params = M.init_params(cfg, KEY)
    B, S = 1, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full_logits, _ = M.forward(params, cfg, {"tokens": tokens})
    _, cache = M.prefill(params, cfg, {"tokens": tokens[:, :S - 1]},
                         max_len=S + 2)
    dec_logits, _ = M.decode_step(params, cfg, cache,
                                  tokens[:, S - 1:S],
                                  jnp.array(S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=2e-3, atol=2e-3)


def test_scan_equals_unrolled():
    cfg = get_config("qwen3-0.6b").reduced(num_layers=4)
    params = M.init_params(cfg, KEY)
    batch = tiny_batch(cfg)
    l1, _ = M.forward(params, cfg, batch, scan_layers=True)
    l2, _ = M.forward(params, cfg, batch, scan_layers=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5,
                               atol=1e-5)


def test_moe_ep_equals_dense():
    """Expert-parallel shard_map MoE == dense reference on a 1-device
    mesh with ample capacity."""
    import functools
    cfg = get_config("olmoe-1b-7b").reduced(num_layers=2,
                                            capacity_factor=8.0)
    params = M.init_params(cfg, KEY)
    batch = tiny_batch(cfg)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    moe_ep = functools.partial(L.moe_ep, mesh=mesh, dp_axes=("data",),
                               ep_axis="model", batch_sharded=True)
    l_dense, _ = M.forward(params, cfg, batch, moe_fn=L.moe_dense)
    with mesh:
        l_ep, _ = M.forward(params, cfg, batch, moe_fn=moe_ep)
    np.testing.assert_allclose(np.asarray(l_dense), np.asarray(l_ep),
                               rtol=2e-4, atol=2e-4)


def test_param_counts_match_actual():
    """Analytic param_counts (used for MODEL_FLOPS) ~ actual leaf sizes."""
    for name in ("qwen3-0.6b", "olmoe-1b-7b", "mamba2-780m"):
        cfg = ARCHS[name].reduced()
        params = M.init_params(cfg, KEY)
        actual = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        total, _ = cfg.param_counts()
        # norms/biases are excluded from the analytic count => small delta
        assert abs(actual - total) / actual < 0.05, (name, actual, total)


def test_long_context_skip_policy():
    assert "long_500k" not in applicable_shapes(get_config("llama3-405b"))
    assert "long_500k" in applicable_shapes(get_config("mamba2-780m"))
    assert "long_500k" in applicable_shapes(get_config("jamba-v0.1-52b"))
    assert "long_500k" in applicable_shapes(get_config("gemma3-27b"))
    assert "long_500k" not in applicable_shapes(get_config("whisper-base"))
