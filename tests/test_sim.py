"""Simulator + EconAdapter + InfraMaps behaviour tests."""
import math

import numpy as np
import pytest

from repro.core.econadapter import AdapterConfig, EconAdapter, GROW, SHRINK
from repro.core.inframaps import PowerAwareInfraMap, MaintenanceInfraMap, \
    InfraMapConfig
from repro.core.market import Market, VolatilityControls
from repro.core.topology import build_cluster
from repro.sim.simulator import ScenarioConfig, run_once
from repro.sim.workloads import Tenant, WorkloadParams
from repro.sim import traces


def small_scenario(**kw):
    base = dict(regime="slight", n_h100=8, n_a100=8, duration_s=3600.0,
                tick_s=60.0, n_training=2, n_inference=2, n_batch=1,
                seed=3)
    base.update(kw)
    return ScenarioConfig(**base)


class TestClouds:
    def test_all_clouds_complete(self):
        cfg = small_scenario()
        for kind in ("fcfs", "fcfsp", "laissez"):
            r = run_once(kind, cfg)
            assert len(r.perf) == 5
            assert all(0.0 <= v <= 1.0 + 1e-9 for v in r.perf.values())
            assert all(c >= 0 for c in r.cost.values())

    def test_laissez_beats_spot_on_average(self):
        """The paper's headline (Fig 6) vs the deployed-cloud analogue:
        continuous negotiation reduces degradation vs FCFS-P (spot) under
        contention. (Vs plain FCFS our synthetic-trace calibration only
        wins in the right-sized regime — the honest deviation documented
        in EXPERIMENTS.md §Fig 6 note.)"""
        means = {}
        for kind in ("fcfs", "fcfsp", "laissez"):
            vals = []
            for seed in (1, 3):
                r = run_once(kind, small_scenario(seed=seed))
                vals.extend(r.perf.values())
            means[kind] = float(np.mean(vals))
        assert means["laissez"] >= means["fcfsp"] - 0.02, means

    def test_retention_metric_well_formed(self):
        """Paper metric (performance retention = multi/alone) is bounded
        and populated for every tenant under every cloud."""
        from repro.sim.simulator import run_with_retention
        for kind in ("fcfs", "laissez"):
            r = run_with_retention(kind, small_scenario(seed=1))
            assert len(r.retention) == 5
            assert all(0.0 <= v <= 1.5 for v in r.retention.values())

    def test_market_activity_happens(self):
        r = run_once("laissez", small_scenario())
        assert r.stats["orders"] > 10
        assert r.stats["transfers"] > 0

    def test_laissez_batch_cloud_completes(self):
        """The JAX batch engine arbitrates the same scenario end to end
        (fourth cloud kind; short horizon — every op is a jitted step)."""
        cfg = small_scenario(duration_s=900.0, tick_s=90.0, n_training=1,
                             n_inference=1, n_batch=0, n_h100=4, n_a100=4)
        r = run_once("laissez_batch", cfg)
        assert len(r.perf) == 2
        assert all(0.0 <= v <= 1.0 + 1e-9 for v in r.perf.values())
        assert all(c >= 0 for c in r.cost.values())
        assert r.stats["orders"] > 0

    def test_laissez_batch_matches_event_cloud(self):
        """Same scenario through the event market and the batch engine:
        the allocation dynamics should produce comparable performance
        (they are step-for-step equivalent engines; adapters quantize
        decisions to ticks, so outcomes track closely)."""
        cfg = small_scenario(duration_s=900.0, tick_s=90.0, n_training=1,
                             n_inference=1, n_batch=0, n_h100=4, n_a100=4)
        ev = run_once("laissez", cfg)
        bt = run_once("laissez_batch", cfg)
        for name in ev.perf:
            assert bt.perf[name] == pytest.approx(ev.perf[name], abs=0.35)

    def test_undersubscribed_converges(self):
        """§5.2: all systems converge when contention disappears."""
        cfg = small_scenario(regime="right_sized", n_training=1,
                             n_inference=1, n_batch=0, n_h100=16,
                             n_a100=16)
        perfs = {k: np.mean(list(run_once(k, cfg).perf.values()))
                 for k in ("fcfs", "laissez")}
        assert abs(perfs["fcfs"] - perfs["laissez"]) < 0.25


class TestEconAdapter:
    def _tenant_market(self):
        topo = build_cluster({"H100": 4, "A100": 4}, gpus_per_host=2,
                             hosts_per_rack=2, racks_per_zone=1)
        m = Market(topo)
        m.set_floor(topo.roots["H100"], 2.0)
        m.set_floor(topo.roots["A100"], 1.0)
        t = Tenant("t", WorkloadParams(kind="training", work=4.0,
                                       deadline_s=3600.0,
                                       checkpoint_interval_s=300.0,
                                       reconfig_s=120.0, max_nodes=4,
                                       value_per_gap=30.0),
                   topo).attach(m)
        return topo, m, t

    def test_listing1_reconfig_cost_lowers_bid(self):
        topo, m, t = self._tenant_market()
        ad = EconAdapter(m, "t", t)
        leaf = topo.leaves_of(topo.roots["H100"])[0]
        bid_cheap = ad.price(leaf, GROW, market_rate=2.0)
        t.last_checkpoint = -600.0          # mid-epoch: restart is costly
        t.last_t = 0.0
        bid_mid = ad.price(leaf, GROW, market_rate=2.0)
        assert bid_mid < bid_cheap

    def test_listing1_shrink_uses_time_till_checkpoint(self):
        topo, m, t = self._tenant_market()
        ad = EconAdapter(m, "t", t)
        leaf = topo.leaves_of(topo.roots["H100"])[0]
        t.last_checkpoint = 0.0
        t.last_t = 0.0                      # full drain ahead
        keep_early = ad.price(leaf, SHRINK, market_rate=2.0)
        t.last_t = 299.0                    # checkpoint imminent: cheap
        keep_late = ad.price(leaf, SHRINK, market_rate=2.0)
        assert keep_late > keep_early

    def test_adapter_acquires_and_prunes(self):
        topo, m, t = self._tenant_market()
        ad = EconAdapter(m, "t", t, AdapterConfig())
        ad.step(0.0)
        assert len(m.owned_leaves("t")) > 0
        t.progress = t.p.work               # done: everything redundant
        t.done_at = 100.0
        for leaf in list(m.owned_leaves("t")):
            assert t.node_redundant(leaf) or True
        ad.step(300.0)
        # redundant nodes relinquished by the adapter
        assert len(m.owned_leaves("t")) <= 1

    def test_misestimation_knob_changes_bids(self):
        topo, m, t = self._tenant_market()
        lo = EconAdapter(m, "t", t, AdapterConfig(
            reconfig_estimate_mult=0.5))
        hi = EconAdapter(m, "t", t, AdapterConfig(
            reconfig_estimate_mult=2.0))
        leaf = topo.leaves_of(topo.roots["H100"])[0]
        t.last_checkpoint = -200.0
        t.last_t = 0.0
        assert lo.price(leaf, GROW, 2.0) > hi.price(leaf, GROW, 2.0)


class TestInfraMaps:
    def test_power_steering_raises_floor(self):
        topo = build_cluster({"H100": 8}, gpus_per_host=2,
                             hosts_per_rack=2, racks_per_zone=1)
        m = Market(topo)
        root = topo.roots["H100"]
        m.set_floor(root, 2.0)
        zone = topo.node(root).children[0]
        imap = PowerAwareInfraMap(m, {zone: [zone]}, power_cap=100.0,
                                  cfg=InfraMapConfig(base_price=2.0))
        imap.observe(0.0, {zone: 50.0})     # comfortable
        f_low = m.floor(topo.leaves_of(zone)[0])
        imap.observe(10.0, {zone: 99.0})    # constrained
        f_high = m.floor(topo.leaves_of(zone)[0])
        assert f_high > f_low

    def test_price_steering_moves_tenant(self):
        """Fig 11 mechanics: raising one row's floor evicts-by-price; the
        tenant's re-bid lands in the cheaper row (migration)."""
        topo = build_cluster({"H100": 8}, gpus_per_host=2,
                             hosts_per_rack=2, racks_per_zone=1)
        m = Market(topo)
        root = topo.roots["H100"]
        m.set_floor(root, 2.0)
        zoneA = topo.node(root).children[0]
        m.place_order("t", zoneA, 3.0, limit=4.0)   # tenant in row A
        leaf = next(iter(m.owned_leaves("t")))
        assert topo.covers(zoneA, leaf)
        m.set_floor(zoneA, 5.0)             # power constrained: price up
        assert m.owner_of(leaf) == "__operator__"   # price-evicted
        # tenant re-bids for "any H100"; row A's floor now blocks it, so
        # the bid matches idle supply in the OTHER row
        m.place_order("t", root, 3.0, limit=4.0)
        moved = next(iter(m.owned_leaves("t")))
        assert not topo.covers(zoneA, moved)   # migrated to the other row

    def test_maintenance_window(self):
        topo = build_cluster({"H100": 4}, gpus_per_host=2,
                             hosts_per_rack=2, racks_per_zone=1)
        m = Market(topo)
        root = topo.roots["H100"]
        m.set_floor(root, 2.0)
        m.place_order("t", root, 3.0, limit=4.0)
        leaf = next(iter(m.owned_leaves("t")))
        host = topo.ancestors(leaf)[1]
        imap = MaintenanceInfraMap(m, InfraMapConfig(base_price=2.0))
        imap.schedule(host, 100.0, 200.0)
        imap.step(150.0)
        assert m.owner_of(leaf) == "__operator__"   # drained by price


class TestTraces:
    def test_llm_rate_positive_and_deterministic(self):
        f1 = traces.llm_request_rate(7, 3600.0, base_rps=10.0)
        f2 = traces.llm_request_rate(7, 3600.0, base_rps=10.0)
        for t in (0.0, 100.0, 3000.0):
            assert f1(t) == f2(t) and f1(t) > 0

    def test_power_rows_jump(self):
        rows = traces.power_rows(1, 3600.0, cap_kw=100.0)
        assert rows["rowA"](600.0) > rows["rowA"](100.0)
        assert rows["rowB"](600.0) < 80.0
