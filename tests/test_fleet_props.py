"""Hypothesis property tests on the vectorized fleet's advance/transfer
invariants (sim/fleet.py).

Split from tests/test_fleet.py so the deterministic fleet tests run on
environments without hypothesis installed (requirements-dev pins it).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.market_jax.engine import TreeSpec
from repro.sim.fleet import Fleet, FleetConfig

N = 4
N_LEAVES = 16
DURATION = 3600.0


def _mk_fleet():
    return Fleet(FleetConfig(n=N, b_max=32),
                 TreeSpec(n_leaves=N_LEAVES, strides=(1, 8, 16, 16, 16)))


def _params(rng):
    f32 = lambda a: jnp.asarray(np.asarray(a, np.float32))  # noqa: E731
    rates = rng.uniform(0.0, 80.0, size=(N, int(DURATION / 10) + 2))
    return {
        "kind": jnp.asarray(rng.integers(0, 3, N).astype(np.int32)),
        "work": f32(rng.uniform(0.2, 4.0, N)),
        "deadline_s": f32(rng.uniform(1200.0, DURATION, N)),
        "checkpoint_interval_s": f32(rng.uniform(60.0, 600.0, N)),
        "reconfig_s": f32(rng.uniform(30.0, 300.0, N)),
        "max_nodes": jnp.asarray(rng.integers(1, 9, N).astype(np.int32)),
        "cap_per_node": f32(rng.uniform(5.0, 15.0, N)),
        "sla_value_per_h": f32(rng.uniform(20.0, 80.0, N)),
        "value_per_gap": f32(rng.uniform(5.0, 40.0, N)),
        "arrival_s": f32(rng.uniform(0.0, 600.0, N)),
        "overhead_mult": f32(np.ones(N)),
        "rates": f32(rates),
    }


schedule_strategy = st.lists(
    st.tuples(
        st.floats(5.0, 400.0),             # dt to the next epoch
        st.lists(st.integers(0, N * N_LEAVES - 1),  # ownership flips
                 min_size=0, max_size=6),
    ), min_size=2, max_size=12)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), sched=schedule_strategy)
def test_advance_and_transfer_invariants(seed, sched):
    """Random ownership churn + advance ticks preserve:
    * progress never decreases across a pure advance, never < 0 overall;
    * cumulative served <= demanded (inference);
    * no progress/served accrues while inside a reconfiguration window;
    * done_at is monotone (once set, it stays);
    * desired_nodes stays within [0, max_nodes]."""
    rng = np.random.default_rng(seed)
    fleet = _mk_fleet()
    params = _params(rng)
    state = fleet.init_state(params)
    owner = np.full(N_LEAVES, -1, np.int64)
    held = jnp.zeros((N,), jnp.int32)
    t = 0.0
    for dt, flips in sched:
        t += dt
        owner_b = owner.copy()
        for f in flips:
            leaf, tid = f % N_LEAVES, f // N_LEAVES
            owner[leaf] = -1 if owner[leaf] == tid else tid
        sel = np.zeros(N_LEAVES, bool)   # every revoke is involuntary
        pre = dict(state)
        state, held = fleet.after_step(
            params, state, t, jnp.asarray(owner_b, jnp.int32),
            jnp.asarray(owner, jnp.int32), jnp.asarray(sel))
        in_window = np.asarray(state["reconfig_until"]) >= t
        mid = dict(state)
        state = fleet.advance(params, state, t, held)
        prog_mid = np.asarray(mid["progress"])
        prog = np.asarray(state["progress"])
        # transfers may waste work, advance may only add
        assert np.all(prog >= prog_mid - 1e-5)
        assert np.all(prog >= 0.0)
        served = np.asarray(state["served"])
        demanded = np.asarray(state["demanded"])
        assert np.all(served <= demanded * (1 + 1e-5) + 1e-3)
        # a tenant still inside its reconfiguration window gains nothing
        # from this tick (active_dt == 0 while now <= reconfig_until)
        stalled = in_window
        assert np.all(prog[stalled] == prog_mid[stalled])
        served_mid = np.asarray(mid["served"])
        assert np.all(served[stalled] == served_mid[stalled])
        done_pre = np.isfinite(np.asarray(pre["done_at"]))
        done = np.isfinite(np.asarray(state["done_at"]))
        assert np.all(done | ~done_pre)
        want = np.asarray(fleet.desired_nodes(params, state, t))
        maxn = np.asarray(params["max_nodes"])
        assert np.all((want >= 0) & (want <= maxn))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_performance_bounded(seed):
    """performance() stays in [0, 1] for any reachable state."""
    rng = np.random.default_rng(seed)
    fleet = _mk_fleet()
    params = _params(rng)
    state = fleet.init_state(params)
    held = jnp.asarray(rng.integers(0, 8, N).astype(np.int32))
    t = 0.0
    for _ in range(6):
        t += float(rng.uniform(10.0, 500.0))
        state = fleet.advance(params, state, t, held)
        perf = np.asarray(fleet.performance(params, state, t))
        assert np.all((perf >= 0.0) & (perf <= 1.0 + 1e-6))
